"""The platform-side freshen scheduler (§2, §3.3) as a concurrent,
multi-instance router.

On every function invocation the scheduler predicts the successors and
dispatches ``freshen`` inside the trigger-delay window — gated by the
Accountant's confidence/service-class/accuracy policy.  Unlike the seed
(one synchronous ``Runtime`` per function), each registered function is
backed by an ``InstancePool`` (repro.core.pool):

* ``invoke``  — acquire an instance (possibly cold-starting or queueing),
  run, release; queueing delay and cold starts are reported to the
  Accountant alongside service time.
* ``submit`` / ``submit_chain`` — admit invocations concurrently; returns
  a Future.  ``submit`` is a *single-submission fast path*: it calls
  ``InstancePool.try_acquire`` inline on the caller thread and, on a warm
  hit, hands only the run-and-release tail to the router executor — one
  hop, ``queue`` phase ≈ the executor handoff.  When nothing is
  immediately available the request parks a **closure** in the pool's
  ``acquire_async`` waiter queue (no router thread blocks on the
  condition variable); ``release`` hands the freed instance straight to
  it.  Freshen prediction (``on_invocation_start``) runs on a dedicated
  single-worker executor off the critical path — observation order is
  preserved (FIFO), admission latency stops paying for it.  Constructing
  the scheduler with ``fast_path=False`` restores the PR 8 two-hop
  admission (the measured baseline in ``benchmarks/hot_path.py``).
* ``submit`` on an unregistered function raises ``UnknownFunction`` at
  admission time (synchronously) rather than surfacing a bare KeyError
  later inside the Future.
* freshen dispatch targets *idle pooled instances* (prewarm-aware): the
  §3.1 hook becomes a pool policy, and with ``PoolConfig.prewarm_provision``
  it proactively cold-starts an instance off the critical path —
  SPES-style provisioning unified with the paper's prediction machinery.

Backwards-compatible single-instance view: ``register`` still returns a
Runtime (the pool's primary instance) and ``self.runtimes`` still maps
function name -> that runtime, so code written against the seed API keeps
working unchanged.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Callable, Deque, Dict, List, Optional

from repro.core.accounting import Accountant
from repro.core.backend import make_backend
from repro.core.pool import InstancePool, PoolConfig
from repro.core.prediction import HybridPredictor, Prediction
from repro.core.runtime import FunctionSpec, Runtime, WarmthLevel
from repro.telemetry import MetricsRegistry, NULL_TRACER, Tracer


class UnknownFunction(KeyError):
    """``submit``/``invoke``/``submit_chain`` named a function that was
    never ``register``-ed with this scheduler.  Raised synchronously at
    admission time — the caller holds a programming error, not a
    capacity problem, so it must not surface later inside a Future the
    way a bare KeyError from the pool lookup used to.  (The cluster
    router already rejects unknowns at route time.)"""

    def __init__(self, fn: str):
        self.fn = fn
        super().__init__(fn)

    def __str__(self) -> str:
        return (f"function {self.fn!r} is not registered with this "
                f"scheduler (call register() first)")


@dataclass
class FreshenEvent:
    fn: str
    confidence: float
    dispatched: bool
    reason: str
    at: float = field(default_factory=time.monotonic)


@dataclass
class WarmthPolicy:
    """Maps prediction confidence to a target warmth rung (SPES-style):
    how warm an instance is worth making is a function of how sure the
    predictor is.  High-regularity functions earn a full HOT prewarm
    (caches populated), medium confidence an INITIALIZED instance
    (servable, caches cold), and the long tail a cheap PROCESS-rung
    sandbox standby.  ``standby_on_gate``: even when the Accountant's
    confidence/accuracy gate refuses a *freshen*, a graded pool may still
    buy the near-free PROCESS standby — the gate protects freshen
    accounting and cache work, not sandbox residency."""

    hot_confidence: float = 0.7
    init_confidence: float = 0.35
    standby_on_gate: bool = True

    def target_level(self, probability: float) -> WarmthLevel:
        if probability >= self.hot_confidence:
            return WarmthLevel.HOT
        if probability >= self.init_confidence:
            return WarmthLevel.INITIALIZED
        return WarmthLevel.PROCESS


class _PrimaryRuntimeView:
    """Seed-compat ``scheduler.runtimes`` mapping: fn -> the pool's live
    primary runtime.  Resolved per access (never a snapshot), so a primary
    reaped by keep-alive expiry is transparently replaced by the pool's
    next (or a freshly provisioned) instance."""

    def __init__(self, pools: Dict[str, InstancePool]):
        self._pools = pools

    def __getitem__(self, fn: str) -> Runtime:
        return self._pools[fn].ensure_primary()

    def get(self, fn: str, default=None):
        pool = self._pools.get(fn)
        return default if pool is None else pool.ensure_primary()

    def __contains__(self, fn: str) -> bool:
        return fn in self._pools

    def __iter__(self):
        return iter(self._pools)

    def keys(self):
        return self._pools.keys()

    def __len__(self):
        return len(self._pools)


class FreshenScheduler:
    """Global scheduling entity: instance pools + predictor + policy."""

    def __init__(self, predictor: Optional[HybridPredictor] = None,
                 accountant: Optional[Accountant] = None,
                 pool_config: Optional[PoolConfig] = None,
                 max_router_threads: int = 16,
                 event_window: int = 4096,
                 warmth_policy: Optional["WarmthPolicy"] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 fast_path: bool = True):
        self.predictor = predictor or HybridPredictor()
        self.accountant = accountant or Accountant()
        self.pool_config = pool_config or PoolConfig()
        # telemetry: NULL_TRACER keeps every span call a constant-cost
        # no-op; a cluster passes one shared tracer to all shards so
        # cross-shard freshens and the arrivals they anchor meet in one
        # pending table
        self.tracer = tracer or NULL_TRACER
        self.metrics = metrics or MetricsRegistry("scheduler.")
        self._m_dispatched = self.metrics.counter("freshen.dispatched")
        self._m_gated = self.metrics.counter("freshen.gated")
        self._m_no_target = self.metrics.counter("freshen.no_target")
        self._m_routed = self.metrics.counter("freshen.routed")
        self._m_e2e = self.metrics.histogram("invoke.e2e_seconds")
        self._m_queue = self.metrics.histogram("invoke.queue_delay_seconds")
        # admission-path split: fast = inline try_acquire hit (run-only
        # work dispatched), slow = parked in the pool's waiter queue or
        # fell back to a blocking acquire (spill/legacy path)
        self._m_fast = self.metrics.counter("invoke.fast_path")
        self._m_slow = self.metrics.counter("invoke.slow_path")
        # None = binary warmth (every prewarm targets HOT — seed behavior);
        # a WarmthPolicy makes prewarm depth confidence-driven
        self.warmth_policy = warmth_policy
        self.max_router_threads = max_router_threads
        # Cross-shard freshen propagation hook (repro.cluster): when set,
        # every prediction is offered to the callback first.  Returning
        # None keeps the prediction shard-local (the target *is* this
        # shard, or no cluster routing applies); returning a bool means
        # the cluster router handled it on whichever shard it decided the
        # predicted invocation will land on — True if the target shard
        # actually dispatched the prewarm, False if its gate dropped it —
        # and the local dispatch path is skipped either way.
        self.freshen_route: Optional[
            Callable[[Prediction], Optional[bool]]] = None
        self.pools: Dict[str, InstancePool] = {}
        self.runtimes = _PrimaryRuntimeView(self.pools)
        # bounded: a long-running platform appends events per invocation
        self.events: Deque[FreshenEvent] = deque(maxlen=event_window)
        self._scopes: Dict[str, tuple] = {}      # chain-level shared scopes
        self._lock = threading.Lock()
        self._router: Optional[ThreadPoolExecutor] = None
        # single-submission fast path toggle: False restores the PR 8
        # two-hop admission (every submit routed through invoke) — the
        # legacy arm benchmarks/hot_path.py measures against
        self.fast_path = fast_path
        # freshen prediction off the critical path: one worker, so
        # predictor.observe keeps its arrival order (the Markov chain
        # detector is order-sensitive) while admission stops paying for
        # prediction + prewarm dispatch
        self._freshen_exec: Optional[ThreadPoolExecutor] = None

    # ------------------------------------------------------------------
    def register(self, spec: FunctionSpec, runtime: Optional[Runtime] = None,
                 scope_group: Optional[str] = None,
                 config: Optional[PoolConfig] = None,
                 backend: Optional[str] = None) -> Runtime:
        """Create the function's instance pool (with one eager instance so
        the seed-era single-runtime API keeps working) and return its
        primary runtime.

        ``scope_group``: §6 "different isolation scopes" — functions in
        the same group share runtime-scoped state (Azure-style chain-level
        isolation): one ``scope`` dict and one ``FreshenCache``, so a
        resource freshened for any member is visible to all of them.
        Every instance the pool ever creates joins the shared scope; each
        keeps its own fr_state (plans differ per function).

        ``backend`` overrides the pool config's instance backend
        (repro.core.backend): "thread" runs hooks in-process, "subprocess"
        in a persistent worker process with measured cold starts,
        "snapshot" in processes forked from a pre-warmed per-pool
        template (measured fork+init cold starts; the template spawns
        here, at register time, off the first arrival's critical path).
        Scope groups are in-process state and require the thread
        backend."""
        # each pool gets its own config copy: tuning one pool must never
        # mutate another's policy through the shared scheduler default
        cfg = config or replace(self.pool_config)
        if backend is not None and backend != cfg.backend:
            cfg = replace(cfg, backend=backend)
        if scope_group is not None and cfg.backend != "thread":
            raise ValueError(
                f"scope_group {scope_group!r} shares in-process state and "
                f"requires the thread backend, not {cfg.backend!r}")

        def factory() -> Runtime:
            rt = Runtime(spec, cold_start_cost=cfg.cold_start_cost,
                         backend=make_backend(cfg.backend))
            self._join_scope(rt, scope_group)
            return rt

        pool = InstancePool(spec, cfg, runtime_factory=factory)
        if runtime is not None:
            self._join_scope(runtime, scope_group)
            pool.adopt(runtime)
        else:
            pool.adopt(factory())
        with self._lock:
            self.pools[spec.name] = pool
        return pool.primary

    def _join_scope(self, rt: Runtime, scope_group: Optional[str]):
        if scope_group is None:
            return
        with self._lock:
            shared = self._scopes.setdefault(scope_group, (rt.scope, rt.cache))
            rt.scope, rt.cache = shared

    def runtime(self, fn: str) -> Runtime:
        return self.runtimes[fn]

    def pool(self, fn: str) -> InstancePool:
        return self.pools[fn]

    def _pool_or_raise(self, fn: str) -> InstancePool:
        pool = self.pools.get(fn)
        if pool is None:
            raise UnknownFunction(fn)
        return pool

    def apply_pool_config(self, fn: str, config: PoolConfig) -> PoolConfig:
        """Live-retune one function's pool (the trace/history-adaptive
        control loop's write path); returns the previous config."""
        return self.pools[fn].reconfigure(config)

    def has_function(self, fn: str) -> bool:
        """Whether ``fn`` is registered — the invocation-target protocol
        shared with ``repro.cluster.ClusterRouter`` (TraceReplayer speaks
        it, so a trace replays into a scheduler or a cluster unchanged)."""
        return fn in self.pools

    def prewarm(self, fn: str, provision: bool = True,
                level: Optional[WarmthLevel] = None
                ) -> List[threading.Thread]:
        """Externally-driven prewarm (oracle replay, cluster rebalancing):
        warm ``fn``'s pool to ``level`` (default HOT — the full freshen
        hook), provisioning off the critical path when nothing is idle."""
        return self.pools[fn].prewarm_freshen(provision=provision,
                                              level=level)

    # ------------------------------------------------------------------
    def _dispatch_freshen(self, pred: Prediction,
                          *, _routed: bool = False) -> bool:
        """Returns True when a prewarm was actually dispatched (locally or
        on the shard the cluster routed it to), False when it was dropped
        (unknown function, accounting gate, no target instance)."""
        if not _routed and self.freshen_route is not None:
            routed = self.freshen_route(pred)
            if routed is not None:
                # the target shard's scheduler traced the dispatch (the
                # fabric shares one tracer); count the routing hop here
                self._m_routed.inc()
                self.events.append(FreshenEvent(
                    pred.fn, pred.probability, bool(routed),
                    "routed-cross-shard" if routed
                    else "routed-cross-shard-gated"))
                return bool(routed)
        pool = self.pools.get(pred.fn)
        if pool is None:
            self._m_no_target.inc()
            self.events.append(FreshenEvent(pred.fn, pred.probability, False,
                                            "no-runtime"))
            return False
        app = pool.spec.app
        level = (WarmthLevel.HOT if self.warmth_policy is None
                 else self.warmth_policy.target_level(pred.probability))
        fspan = self.tracer.freshen(
            pred.fn, confidence=pred.probability, level=level.label,
            expected_delay=pred.expected_delay)
        if not self.accountant.should_freshen(app, pred.probability):
            if (self.warmth_policy is not None
                    and self.warmth_policy.standby_on_gate
                    and pool.config.graded_warmth):
                # the gate refused the freshen, not sandbox residency:
                # a PROCESS-rung standby is the long-tail consolation
                threads = pool.prewarm_freshen(level=WarmthLevel.PROCESS)
                if threads:
                    self._m_dispatched.inc()
                    fspan.dispatched("standby-process")
                    self.events.append(FreshenEvent(
                        pred.fn, pred.probability, True, "standby-process"))
                    return True
            self._m_gated.inc()
            fspan.gated("policy-gated")
            self.events.append(FreshenEvent(pred.fn, pred.probability, False,
                                            "policy-gated"))
            return False
        # fabriclint: allow[clock] -- measured service/freshen time is a wall-clock contract
        t0 = time.monotonic()
        threads = pool.prewarm_freshen(level=level)
        if not threads:
            self._m_no_target.inc()
            fspan.gated("no-idle-instance")
            self.events.append(FreshenEvent(pred.fn, pred.probability, False,
                                            "no-idle-instance"))
            return False
        self._m_dispatched.inc()
        fspan.dispatched("dispatched" if level >= WarmthLevel.HOT
                         else f"dispatched-{level.label}")
        self.events.append(FreshenEvent(
            pred.fn, pred.probability, True,
            "dispatched" if level >= WarmthLevel.HOT
            else f"dispatched-{level.label}"))

        if level >= WarmthLevel.HOT:
            # freshen accounting tracks cache-population work; partial
            # warms never touch the caches and must not skew the paper's
            # accuracy gate
            def _account():
                for th in threads:
                    th.join()
                fspan.dispatch_done()
                self.accountant.record_freshen(
                    # fabriclint: allow[clock] -- measured service/freshen time is a wall-clock contract
                    app, pred.fn, time.monotonic() - t0,
                    expected_delay=pred.expected_delay)

            threading.Thread(target=_account, daemon=True).start()
        return True

    def on_invocation_start(self, fn: str, now: Optional[float] = None):
        """Called when fn begins: the best moment to freshen successors —
        the successor will not start until fn finishes + trigger delay.
        ``now`` lets the fast path stamp the *admission* time even though
        this runs later on the freshen executor (prediction inter-arrival
        statistics must not absorb executor lag)."""
        self.predictor.observe(fn, time.monotonic() if now is None else now)
        for pred in self.predictor.successors(fn):
            self._dispatch_freshen(pred)

    def _ensure_freshen_exec(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._freshen_exec is None:
                self._freshen_exec = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="freshen-predict")
            return self._freshen_exec

    def _freshen_async(self, fn: str):
        """Queue prediction + prewarm dispatch for ``fn``'s admission on
        the dedicated freshen executor — off the request critical path."""
        # fabriclint: allow[clock] -- measured service/freshen time is a wall-clock contract
        now = time.monotonic()
        try:
            self._ensure_freshen_exec().submit(
                self.on_invocation_start, fn, now)
        except RuntimeError:
            pass      # shutting down: predictions are best-effort

    # ------------------------------------------------------------------
    def invoke(self, fn: str, args=None, freshen_successors: bool = True,
               acquire_timeout: Optional[float] = None, _span=None):
        """Run fn on a pooled instance with full bookkeeping: predecessor
        prediction, instance acquisition (cold start / queueing), service
        timing, and latency accounting.

        ``_span``: an open ``InvocationSpan`` handed down by an outer
        layer (``submit`` stamps admission time there; the cluster router
        opens it around placement).  When absent one is opened here, so
        direct ``invoke`` callers still trace."""
        pool = self._pool_or_raise(fn)
        span = _span if _span is not None else self.tracer.invocation(
            fn, app=pool.spec.app)
        if span.enabled and span.submitted_at is not None:
            # the router-executor hop: admission -> this thread
            span.phase_from("queue", span.submitted_at)
        try:
            if freshen_successors:
                with span.phase("route"):
                    self.on_invocation_start(fn)
            with span.phase("acquire"):
                inst, queue_delay, cold = pool.acquire(
                    timeout=acquire_timeout)
            span.annotate(queue_delay=queue_delay, cold=cold)
            # fabriclint: allow[clock] -- measured service/freshen time is a wall-clock contract
            t0 = time.monotonic()
            try:
                # activate so Runtime's lazy boot path attaches
                # boot_process/boot_init phases to this invocation
                with span.phase("run"), span.active():
                    result = inst.runtime.run(args)
            finally:
                with span.phase("release"):
                    pool.release(inst)
        except BaseException as exc:
            span.finish(error=type(exc).__name__)
            raise
        # accounting only on success (seed semantics): a raising function
        # body must not be billed, skew latency percentiles, or credit
        # pending freshens as useful
        # fabriclint: allow[clock] -- measured service/freshen time is a wall-clock contract
        service = time.monotonic() - t0
        self._m_e2e.observe(queue_delay + service)
        self._m_queue.observe(queue_delay)
        span.finish()
        self.accountant.record_invocation(
            pool.spec.app, fn, service,
            queue_delay=queue_delay, cold_start=cold)
        return result

    def _run_acquired(self, fn: str, pool: InstancePool, inst, args,
                      span, queue_delay: float, cold: bool):
        """Run-and-release tail of an admission whose acquire already
        happened (fast-path ``try_acquire`` hit or ``acquire_async``
        grant).  Same bookkeeping contract as ``invoke``: accounting on
        success only, span finished on every path."""
        if span.enabled and span.submitted_at is not None:
            # admission -> this thread: the only hop the fast path pays
            span.phase_from("queue", span.submitted_at)
        span.annotate(queue_delay=queue_delay, cold=cold)
        # fabriclint: allow[clock] -- measured service/freshen time is a wall-clock contract
        t0 = time.monotonic()
        try:
            try:
                with span.phase("run"), span.active():
                    result = inst.runtime.run(args)
            finally:
                with span.phase("release"):
                    pool.release(inst)
        except BaseException as exc:
            span.finish(error=type(exc).__name__)
            raise
        # fabriclint: allow[clock] -- measured service/freshen time is a wall-clock contract
        service = time.monotonic() - t0
        self._m_e2e.observe(queue_delay + service)
        self._m_queue.observe(queue_delay)
        span.finish()
        self.accountant.record_invocation(
            pool.spec.app, fn, service,
            queue_delay=queue_delay, cold_start=cold)
        return result

    def run_chain(self, fns: List[str], args=None,
                  freshen: bool = True):
        """Execute an explicit chain sequentially (orchestration-style)."""
        out = args
        for fn in fns:
            out = self.invoke(fn, out, freshen_successors=freshen)
        return out

    # ------------------------------------------------------------------
    # The thread-pool router: concurrent admission.
    def _ensure_router(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._router is None:
                self._router = ThreadPoolExecutor(
                    max_workers=self.max_router_threads,
                    thread_name_prefix="freshen-router")
            return self._router

    def submit(self, fn: str, args=None, freshen_successors: bool = True,
               acquire_timeout: Optional[float] = None,
               _span=None) -> Future:
        """Admit one invocation; returns a Future for the function result.

        Single-submission fast path: ``try_acquire`` runs inline on the
        caller thread — a warm hit dispatches only the run-and-release
        tail to the router (one hop, ``invoke.fast_path``).  On a miss
        (``invoke.slow_path``): with an ``acquire_timeout`` the request
        takes the legacy blocking-acquire path unchanged, so spill
        semantics (``PoolSaturated`` surfacing on the Future within the
        deadline) are exactly the PR 8 behavior; without one it parks a
        closure in the pool's admission-ordered ``acquire_async`` queue
        and the next ``release`` hands it the freed instance directly.
        Raises ``UnknownFunction`` synchronously for an unregistered
        ``fn``."""
        pool = self._pool_or_raise(fn)
        if _span is None:
            _span = self.tracer.invocation(fn, app=pool.spec.app)
        if not self.fast_path:
            _span.mark_submitted()
            return self._ensure_router().submit(
                self.invoke, fn, args, freshen_successors, acquire_timeout,
                _span)
        with _span.phase("acquire"):
            grabbed = pool.try_acquire()
        _span.mark_submitted()
        if grabbed is not None:
            inst, cold = grabbed
            self._m_fast.inc()
            if freshen_successors:
                self._freshen_async(fn)
            return self._ensure_router().submit(
                self._run_acquired, fn, pool, inst, args, _span, 0.0, cold)
        self._m_slow.inc()
        if acquire_timeout is not None:
            # spill path unchanged: blocking acquire with a deadline in a
            # router thread (the cluster's retry chain needs PoolSaturated
            # raised from the acquire, not a swept waiter)
            return self._ensure_router().submit(
                self.invoke, fn, args, freshen_successors, acquire_timeout,
                _span)
        if freshen_successors:
            self._freshen_async(fn)
        fut: Future = Future()
        fut.set_running_or_notify_cancel()

        def _granted(inst, queue_delay, cold, error):
            if error is not None:
                _span.finish(error=type(error).__name__)
                fut.set_exception(error)
                return
            # the waiter left the pool queue before this callback runs, so
            # shutdown's drain (which watches async_waiting_count) can kill
            # the router inside that window; don't _ensure_router here —
            # that would resurrect a leaked executor after shutdown
            with self._lock:
                router = self._router
            if router is not None:
                try:
                    inner = router.submit(
                        self._run_acquired, fn, pool, inst, args, _span,
                        queue_delay, cold)
                except RuntimeError:
                    router = None      # shut down between grant and handoff
                else:
                    inner.add_done_callback(lambda f: (
                        fut.set_exception(f.exception())
                        if f.exception() is not None
                        else fut.set_result(f.result())))
            if router is None:
                # run the tail inline on the releasing thread — an
                # admitted future is never dropped
                try:
                    fut.set_result(self._run_acquired(
                        fn, pool, inst, args, _span, queue_delay, cold))
                except BaseException as exc:
                    fut.set_exception(exc)

        pool.acquire_async(_granted)
        return fut

    def submit_chain(self, fns: List[str], args=None,
                     freshen: bool = True) -> Future:
        """Admit a function chain; returns a Future for the final link's
        result.  Tracing parity with ``submit``: a parent span (named
        ``chain:a->b->…``) stamps admission and the router hop as its
        ``queue`` phase, and every link runs under its own child span
        (annotated with the parent id and link index) exactly as a
        single submit would.  Raises ``UnknownFunction`` synchronously
        when any link is unregistered."""
        if not fns:
            raise ValueError("submit_chain: empty chain")
        pools = [self._pool_or_raise(fn) for fn in fns]
        span = self.tracer.invocation(
            "chain:" + "->".join(fns), app=pools[0].spec.app,
            chain=list(fns))
        span.mark_submitted()
        return self._ensure_router().submit(
            self._run_chain_traced, fns, args, freshen, span)

    def _run_chain_traced(self, fns: List[str], args, freshen: bool, span):
        if span.enabled and span.submitted_at is not None:
            span.phase_from("queue", span.submitted_at)
        out = args
        try:
            for i, fn in enumerate(fns):
                child = None
                if span.enabled:
                    child = self.tracer.invocation(
                        fn, app=self.pools[fn].spec.app,
                        chain_parent=span.span_id, link=i)
                    child.mark_submitted()
                out = self.invoke(fn, out, freshen_successors=freshen,
                                  _span=child)
        except BaseException as exc:
            span.finish(error=type(exc).__name__)
            raise
        span.finish()
        return out

    def shutdown(self, wait: bool = True):
        """Stop the router; with ``wait=True`` (the default) also close
        every pool's idle instances once in-flight work has drained —
        terminating subprocess backend workers so platforms never leak
        processes.  Pools stay usable afterwards (they re-provision).
        Closure-parked admissions are not router tasks yet, so the drain
        first waits for the pools' waiter queues to empty (releases from
        in-flight runs serve them) before stopping the router."""
        if wait:
            while any(p.async_waiting_count()
                      for p in list(self.pools.values())):
                time.sleep(0.001)
        with self._lock:
            router, self._router = self._router, None
            fexec, self._freshen_exec = self._freshen_exec, None
        if fexec is not None:
            fexec.shutdown(wait=wait)
        if router is not None:
            router.shutdown(wait=wait)
        if wait:
            for pool in list(self.pools.values()):
                pool.close()

    # ------------------------------------------------------------------
    def platform_stats(self) -> dict:
        """Pool + freshen counters across every registered function."""
        return {name: {**pool.stats(), **pool.freshen_stats()}
                for name, pool in self.pools.items()}

    def metrics_snapshot(self) -> dict:
        """Unified registry dump: the scheduler's own instruments plus
        every pool's (each registry's prefix — ``scheduler.`` /
        ``pool.<fn>.`` — is baked into its snapshot keys, so the merge
        is flat)."""
        out = dict(self.metrics.snapshot())
        for pool in list(self.pools.values()):
            out.update(pool.metrics.snapshot())
        return out
