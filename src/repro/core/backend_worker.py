"""The subprocess-backend worker: one sandboxed instance per process.

Spawned by ``repro.core.backend.SubprocessBackend`` as
``python -m repro.core.backend_worker``.  Speaks the length-prefixed
pickle frame protocol on stdin/stdout: the parent sends ``(cmd, payload)``
and gets back ``("ok", result)`` or ``("err", traceback_text)``.

Commands:

* ``init``    — extend ``sys.path``, materialize the ``FunctionSpec``
  (``spec_ref`` = ``"module:attr"`` resolving to a spec or a zero-arg
  factory, else ``spec_pickle`` bytes), build a thread-backed ``Runtime``
  and run its init hook.  The wall time the *parent* measures around this
  round-trip — interpreter exec, imports, ``init_fn`` — is the real cold
  start.
* ``run``     — execute the run hook with the unpickled args.
* ``freshen`` — execute the freshen hook (Algorithm 2) to completion.
* ``stats``   — fr_state counters plus run/freshen hook counts.
* ``exit``    — acknowledge and terminate.  EOF on stdin (parent gone)
  also terminates, so workers never outlive their platform.

The post-init command loop lives in ``serve()`` so snapshot-backend forks
(``repro.core.backend_template``) speak the identical protocol over their
unix-socket channel: one wire contract, two transports.

File descriptor 1 is re-pointed at stderr before any user code runs: a
function body that prints can never corrupt the protocol stream.
"""
from __future__ import annotations

import importlib
import os
import pickle
import sys
import traceback


def _resolve_spec(payload):
    from repro.core.runtime import FunctionSpec
    ref = payload.get("spec_ref")
    if ref:
        mod_name, _, attr = ref.partition(":")
        if not mod_name or not attr:
            raise ValueError(f"spec_ref must be 'module:attr', got {ref!r}")
        obj = getattr(importlib.import_module(mod_name), attr)
        if not isinstance(obj, FunctionSpec):
            obj = obj()
        if not isinstance(obj, FunctionSpec):
            raise TypeError(f"spec_ref {ref!r} did not yield a FunctionSpec")
        return obj
    return pickle.loads(payload["spec_pickle"])


def serve(proto_in, proto_out, runtime) -> None:
    """The booted-instance command loop (run/freshen/stats/exit), shared
    by the pipe worker and snapshot-template forks.  Returns on ``exit``
    or channel EOF; hook exceptions are reported as ``("err", tb)`` frames
    and the loop continues — an instance survives a failing run hook."""
    from repro.core.backend import read_frame, write_frame

    while True:
        msg = read_frame(proto_in)
        if msg is None:                      # parent closed the channel
            return
        cmd, payload = msg
        try:
            if cmd == "run":
                write_frame(proto_out, ("ok", runtime.run(payload)))
            elif cmd == "freshen":
                runtime.freshen(blocking=True)
                write_frame(proto_out, ("ok", runtime.fr_state.stats()))
            elif cmd == "stats":
                stats = dict(runtime.fr_state.stats())
                stats["run_count"] = runtime.run_count
                stats["freshen_count"] = runtime.freshen_count
                write_frame(proto_out, ("ok", stats))
            elif cmd == "exit":
                write_frame(proto_out, ("ok", None))
                return
            else:
                write_frame(proto_out, ("err", f"unknown command {cmd!r}"))
        except BaseException:
            try:
                write_frame(proto_out, ("err", traceback.format_exc()))
            except (BrokenPipeError, OSError):
                return


def main() -> int:
    # claim the protocol channel, then point fd 1 at stderr so user-code
    # prints (and library chatter) land in the parent's stderr instead
    proto_out = os.fdopen(os.dup(sys.stdout.fileno()), "wb")
    os.dup2(sys.stderr.fileno(), sys.stdout.fileno())
    proto_in = sys.stdin.buffer

    from repro.core.backend import read_frame, write_frame

    runtime = None
    while runtime is None:
        msg = read_frame(proto_in)
        if msg is None:                      # parent closed the pipe
            return 0
        cmd, payload = msg
        try:
            if cmd == "init":
                for p in payload.get("sys_path", []):
                    if p and p not in sys.path:
                        sys.path.append(p)
                spec = _resolve_spec(payload)
                from repro.core.runtime import Runtime
                runtime = Runtime(spec)      # thread-backed inside the worker
                runtime.init()
                write_frame(proto_out, ("ok", {
                    "init_seconds": runtime.init_seconds,
                    "plan_len": len(runtime.fr_state.plan),
                    "pid": os.getpid(),
                }))
            elif cmd == "exit":
                write_frame(proto_out, ("ok", None))
                return 0
            else:
                write_frame(proto_out, ("err",
                                        f"not initialized (command {cmd!r})"))
        except BaseException:
            runtime = None
            try:
                write_frame(proto_out, ("err", traceback.format_exc()))
            except BrokenPipeError:
                return 0
    serve(proto_in, proto_out, runtime)
    return 0


if __name__ == "__main__":
    sys.exit(main())
