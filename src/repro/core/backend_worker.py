"""The subprocess-backend worker: one sandboxed instance per process.

Spawned by ``repro.core.backend.SubprocessBackend`` as
``python -m repro.core.backend_worker``.  Speaks the length-prefixed
pickle frame protocol on stdin/stdout: the parent sends ``(cmd, payload)``
and gets back ``("ok", result)`` or ``("err", traceback_text)``.

The command loop mirrors the warmth ladder: a freshly spawned worker *is*
the PROCESS rung (interpreter up, function un-inited), ``init`` climbs to
INITIALIZED, and ``demote`` walks back down without tearing the process
down.

Commands:

* ``load``    — extend ``sys.path`` and materialize the ``FunctionSpec``
  (``spec_ref`` = ``"module:attr"`` resolving to a spec or a zero-arg
  factory, else ``spec_pickle`` bytes).  No runtime is built: the wall
  time the parent measures around spawn + this round-trip is the PROCESS
  rung's cost.
* ``init``    — build a thread-backed ``Runtime`` from the loaded spec
  and run its init hook (``init_fn`` + plan build — the INITIALIZED
  rung).  For compat the payload may carry the spec/sys_path inline
  (legacy single-shot boot); ``record: true`` additionally reports the
  modules the init pulled in beyond the pre-init baseline (the snapshot
  template's REAP working-set probe).
* ``demote``  — release warmth: ``level >= 2`` invalidates the fr caches
  (HOT -> INITIALIZED); ``level <= 1`` drops the runtime entirely while
  the process stays resident (-> PROCESS).
* ``run``     — execute the run hook with the unpickled args.
* ``freshen`` — execute the freshen hook (Algorithm 2) to completion.
* ``stats``   — fr_state counters plus run/freshen hook counts.
* ``exit``    — acknowledge and terminate.  EOF on stdin (parent gone)
  also terminates, so workers never outlive their platform.

The loop lives in ``serve()`` so snapshot-backend forks
(``repro.core.backend_template``) speak the identical protocol over their
unix-socket channel: one wire contract, two transports (a fork enters
``serve`` with its spec pre-loaded — the template already resolved it).

File descriptor 1 is re-pointed at stderr before any user code runs: a
function body that prints can never corrupt the protocol stream.
"""
from __future__ import annotations

import importlib
import os
import pickle
import sys
import traceback


def _resolve_spec(payload):
    from repro.core.runtime import FunctionSpec
    ref = payload.get("spec_ref")
    if ref:
        mod_name, _, attr = ref.partition(":")
        if not mod_name or not attr:
            raise ValueError(f"spec_ref must be 'module:attr', got {ref!r}")
        obj = getattr(importlib.import_module(mod_name), attr)
        if not isinstance(obj, FunctionSpec):
            obj = obj()
        if not isinstance(obj, FunctionSpec):
            raise TypeError(f"spec_ref {ref!r} did not yield a FunctionSpec")
        return obj
    return pickle.loads(payload["spec_pickle"])


def _extend_sys_path(payload) -> None:
    for p in payload.get("sys_path", []):
        if p and p not in sys.path:
            sys.path.append(p)


def serve(proto_in, proto_out, runtime=None, spec=None) -> None:
    """The instance command loop, shared by the pipe worker and snapshot-
    template forks.  Returns on ``exit`` or channel EOF; hook exceptions
    are reported as ``("err", tb)`` frames and the loop continues — an
    instance survives a failing run hook, and a failing ``init`` leaves
    the worker at the PROCESS rung for a clean retry."""
    from repro.core.backend import read_frame, write_frame
    from repro.core.runtime import Runtime, WarmthLevel

    while True:
        msg = read_frame(proto_in)
        if msg is None:                      # parent closed the channel
            return
        cmd, payload = msg
        try:
            if cmd == "load":
                _extend_sys_path(payload)
                spec = _resolve_spec(payload)
                runtime = None
                write_frame(proto_out, ("ok", {"pid": os.getpid()}))
            elif cmd == "init":
                payload = payload or {}
                if "spec_ref" in payload or "spec_pickle" in payload:
                    _extend_sys_path(payload)
                    spec = _resolve_spec(payload)
                if spec is None:
                    write_frame(proto_out,
                                ("err", "no spec loaded (command 'init')"))
                    continue
                record = bool(payload.get("record"))
                baseline = set(sys.modules) if record else None
                runtime = None
                rt = Runtime(spec)           # thread-backed inside the worker
                rt.init()
                runtime = rt
                info = {
                    "init_seconds": runtime.init_seconds,
                    "plan_len": len(runtime.fr_state.plan),
                    "pid": os.getpid(),
                }
                if record:
                    info["imported"] = sorted(set(sys.modules) - baseline)
                write_frame(proto_out, ("ok", info))
            elif cmd == "demote":
                level = WarmthLevel(int((payload or {}).get("level", 0)))
                if runtime is not None:
                    if level >= WarmthLevel.INITIALIZED:
                        runtime.demote_to(level)
                    else:
                        runtime = None       # process stays resident
                write_frame(proto_out, ("ok", {"level": int(level)}))
            elif cmd == "run":
                if runtime is None:
                    write_frame(proto_out,
                                ("err", "not initialized (command 'run')"))
                else:
                    write_frame(proto_out, ("ok", runtime.run(payload)))
            elif cmd == "freshen":
                if runtime is None:
                    write_frame(proto_out,
                                ("err",
                                 "not initialized (command 'freshen')"))
                else:
                    runtime.freshen(blocking=True)
                    write_frame(proto_out, ("ok", runtime.fr_state.stats()))
            elif cmd == "stats":
                if runtime is None:
                    write_frame(proto_out,
                                ("err", "not initialized (command 'stats')"))
                else:
                    stats = dict(runtime.fr_state.stats())
                    stats["run_count"] = runtime.run_count
                    stats["freshen_count"] = runtime.freshen_count
                    write_frame(proto_out, ("ok", stats))
            elif cmd == "exit":
                write_frame(proto_out, ("ok", None))
                return
            else:
                write_frame(proto_out, ("err", f"unknown command {cmd!r}"))
        except BaseException:
            try:
                write_frame(proto_out, ("err", traceback.format_exc()))
            except (BrokenPipeError, OSError):
                return


def main() -> int:
    # claim the protocol channel, then point fd 1 at stderr so user-code
    # prints (and library chatter) land in the parent's stderr instead
    proto_out = os.fdopen(os.dup(sys.stdout.fileno()), "wb")
    os.dup2(sys.stderr.fileno(), sys.stdout.fileno())
    proto_in = sys.stdin.buffer
    serve(proto_in, proto_out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
