"""Pytree checkpointing: flatten params by key-path and store as .npz, with
a version counter and atomic writes.  No external deps (orbax not available);
this is the substrate WeightStore and the trainer build on."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_pytree(path: str, tree, metadata: dict | None = None) -> None:
    """Atomic save: write to a temp file in the same dir, then rename.
    bfloat16 (unknown to vanilla numpy IO) is stored as a uint16 view with
    the true dtype recorded in the metadata."""
    flat = _flatten_with_paths(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    treedef = jax.tree_util.tree_structure(tree)
    dtype_map = {}
    for k, v in list(flat.items()):
        if v.dtype.kind not in "biufc":          # e.g. bfloat16 -> void
            dtype_map[k] = str(v.dtype)
            flat[k] = v.view(np.uint16)
    meta = {"treedef": str(treedef), "dtype_map": dtype_map,
            **(metadata or {})}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, like) -> Any:
    """Load into the structure of ``like`` (a template pytree or its
    eval_shape); leaf order is matched by key path."""
    import ml_dtypes
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat = {k: z[k] for k in z.files if k != "__meta__"}
    dtype_map = meta.get("dtype_map", {})
    for k, dt in dtype_map.items():
        flat[k] = flat[k].view(np.dtype(getattr(ml_dtypes, dt)))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        key = "/".join(_path_str(p) for p in path_elems)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want = np.dtype(leaf.dtype)
        leaves.append(jax.numpy.asarray(arr).astype(want))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with np.load(path, allow_pickle=False) as z:
        return json.loads(str(z["__meta__"]))
