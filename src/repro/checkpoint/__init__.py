from repro.checkpoint.io import load_metadata, load_pytree, save_pytree  # noqa: F401
