"""Pure-jnp oracles for every Pallas kernel (no chunking, no online softmax —
the most literal formulation possible)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None):
    """q: (B,Sq,Hq,dh); k/v: (B,Sk,Hkv,dh|dv).  Naive full softmax."""
    B, Sq, Hq, dh = q.shape
    _, Sk, Hkv, dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qh = q.reshape(B, Sq, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kv_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, dv).astype(q.dtype)


def ref_decode_attention(q, k_cache, v_cache, pos, *, window=None,
                         softcap=None, scale=None):
    """Mirror of models.layers.decode_attention (including ring buffers)."""
    from repro.models.layers import decode_attention
    return decode_attention(q, k_cache, v_cache, pos, window=window,
                            softcap=softcap, scale=scale)


def ref_rglru_scan(a, b, h0=None):
    """Literal sequential recurrence h_t = a_t h_{t-1} + b_t."""
    B, S, r = a.shape
    h = jnp.zeros((B, r), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    h, ys = jax.lax.scan(step, h, (a.transpose(1, 0, 2).astype(jnp.float32),
                                   b.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2), h
