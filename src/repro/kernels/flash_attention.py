"""Pallas TPU flash-attention (prefill) kernel.

Grid = (B, Hq, nq, nk) with the KV dimension innermost; the online-softmax
running state (m, l, acc) lives in VMEM scratch and persists across the nk
steps (TPU grids execute sequentially).  BlockSpecs tile Q/K/V into VMEM:
one (q_blk × dh) query tile and one (kv_blk × dh) KV tile at a time, so VMEM
footprint is q_blk·dh + 2·kv_blk·dh + q_blk·(dh + kv_blk) floats.  dh and the
block minor dims should be multiples of 128 for MXU alignment on real TPUs;
tests run interpret=True on CPU.

GQA is expressed in the K/V index_map (query head h reads KV head h // G).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, softcap, q_blk, kv_blk, nk):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (q_blk, dh)
    k = k_ref[0, 0].astype(jnp.float32)                 # (kv_blk, dh)
    v = v_ref[0, 0].astype(jnp.float32)                 # (kv_blk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
    kv_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
    mask = jnp.ones((q_blk, kv_blk), jnp.bool_)
    if causal:
        mask &= q_pos >= kv_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal=True, window=None, softcap=None,
                           scale=None, q_blk=128, kv_blk=128,
                           interpret=False):
    """q: (B, Sq, Hq, dh); k/v: (B, Sk, Hkv, dh|dv) -> (B, Sq, Hq, dv)."""
    B, Sq, Hq, dh = q.shape
    _, Sk, Hkv, dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Sk)
    assert Sq % q_blk == 0 and Sk % kv_blk == 0
    nq, nk = Sq // q_blk, Sk // kv_blk

    qh = q.transpose(0, 2, 1, 3)                        # (B, Hq, Sq, dh)
    kh = k.transpose(0, 2, 1, 3)                        # (B, Hkv, Sk, dh)
    vh = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_blk=q_blk, kv_blk=kv_blk, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_blk, dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_blk, dv),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, dv),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, dv), q.dtype),
        scratch_shapes=[
            _vmem((q_blk,), jnp.float32),
            _vmem((q_blk,), jnp.float32),
            _vmem((q_blk, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
