"""Jit'd public wrappers for the Pallas kernels.

``use_pallas``: on a real TPU backend this dispatches to the Mosaic-lowered
kernels; on CPU (this container) ``interpret=True`` executes the kernel body
in Python for correctness validation, and the model substrate defaults to the
pure-jnp blockwise implementations (see DESIGN.md — the paper has no kernel
contribution; kernels serve the framework's serving hot paths).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.rglru_scan import rglru_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "q_blk", "kv_blk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, q_blk=128, kv_blk=128, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_blk=q_blk, kv_blk=kv_blk, interpret=interpret)


@partial(jax.jit, static_argnames=("window", "softcap", "scale", "kv_blk",
                                   "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, window=None, softcap=None,
                     scale=None, kv_blk=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return decode_attention_kernel(
        q, k_cache, v_cache, pos, window=window, softcap=softcap, scale=scale,
        kv_blk=kv_blk, interpret=interpret)


@partial(jax.jit, static_argnames=("r_blk", "interpret"))
def rglru_scan(a, b, h0=None, *, r_blk=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return rglru_scan_kernel(a, b, h0, r_blk=r_blk, interpret=interpret)
