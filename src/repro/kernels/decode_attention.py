"""Pallas TPU single-token (decode) attention kernel over a KV cache.

Grid = (B, Hq, nk), KV innermost; per-(b,h) running (m, l, acc) scalars/rows
in VMEM scratch.  Supports ring-buffered local windows: validity of slot j is
derived from the current position (SMEM-prefetched per-row scalar), matching
``repro.models.layers.decode_attention`` semantics exactly.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale, softcap, window, ring, kv_blk, nk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, dh)
    k = k_ref[0, 0].astype(jnp.float32)                 # (kv_blk, dh)
    v = v_ref[0, 0].astype(jnp.float32)                 # (kv_blk, dv)
    pos = pos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    slots = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (1, kv_blk), 1)
    if ring:
        # slot j holds absolute position pos - ((pos - j) mod W); true mod
        delta = jax.lax.rem(jax.lax.rem(pos - slots, window) + window, window)
        valid = (pos - delta) >= 0
    else:
        valid = slots <= pos
        if window is not None:
            valid &= (pos - slots) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, pos, *, window=None,
                            softcap=None, scale=None, kv_blk=256,
                            interpret=False):
    """q: (B, 1, Hq, dh); k/v_cache: (B, S, Hkv, dh|dv); pos: (B,) int32."""
    B, _, Hq, dh = q.shape
    _, S, Hkv, dv = v_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    kv_blk = min(kv_blk, S)
    assert S % kv_blk == 0
    nk = S // kv_blk
    ring = window is not None and S == window

    qh = q.transpose(0, 2, 1, 3)                        # (B, Hq, 1, dh)
    kh = k_cache.transpose(0, 2, 1, 3)
    vh = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _decode_kernel, scale=scale, softcap=softcap, window=window,
        ring=ring, kv_blk=kv_blk, nk=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, Hq, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, kv_blk, dh),
                         lambda b, h, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_blk, dv),
                         lambda b, h, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dv), lambda b, h, ki: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, dv), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), qh, kh, vh)
    return out.transpose(0, 2, 1, 3)
