"""Pallas TPU kernel for the RG-LRU sequential scan.

The gate computations (block-diagonal matmuls + sigmoids) are cheap and fuse
well in XLA, so the kernel takes the precomputed per-step decay ``a`` and
input ``b`` (both f32) and runs the recurrence  h_t = a_t * h_{t-1} + b_t
sequentially in VMEM.  Grid = (B_tiles, r_tiles); each program holds its
(S × r_blk) slice of a/b in VMEM (2·S·r_blk·4 bytes — r_blk chosen so this
fits) and carries h in a VMEM scratch row.

On TPU this trades the log(S)-depth associative scan (which materializes
O(S·r) intermediates in HBM at every level) for a single VMEM-resident pass;
it is also the decode-friendly formulation.  Validated in interpret mode
against ``repro.models.rglru.rglru_scan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hT_ref, *, seq_len):
    a = a_ref[0]                                        # (S, r_blk) f32
    b = b_ref[0]
    h0 = h0_ref[0]                                      # (r_blk,)

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        y_ref[0, t] = h
        return h

    hT = jax.lax.fori_loop(0, seq_len, step, h0)
    hT_ref[0] = hT


def rglru_scan_kernel(a, b, h0=None, *, b_blk=1, r_blk=256, interpret=False):
    """a, b: (B, S, r) f32 decay/input sequences; h0: (B, r) initial state.

    Returns (y: (B, S, r) f32, h_final: (B, r) f32).
    """
    B, S, r = a.shape
    r_blk = min(r_blk, r)
    assert r % r_blk == 0
    if h0 is None:
        h0 = jnp.zeros((B, r), jnp.float32)

    kernel = functools.partial(_rglru_kernel, seq_len=S)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, r // r_blk),
        in_specs=[
            pl.BlockSpec((1, S, r_blk), lambda bi, ri: (bi, 0, ri)),
            pl.BlockSpec((1, S, r_blk), lambda bi, ri: (bi, 0, ri)),
            pl.BlockSpec((1, r_blk), lambda bi, ri: (bi, ri)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, r_blk), lambda bi, ri: (bi, 0, ri)),
            pl.BlockSpec((1, r_blk), lambda bi, ri: (bi, ri)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, r), jnp.float32),
            jax.ShapeDtypeStruct((B, r), jnp.float32),
        ],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32))
    return y, hT
