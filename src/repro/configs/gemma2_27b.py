"""Gemma-2 27B: alternating local(4096-window)/global attention, logit
softcaps, sandwich norms, scaled embedding.  [arXiv:2408.00118]

long_context_ok: half the layers are 4k-window local; the global layers
decode in O(L) per token against a mesh-sharded KV cache, so long_500k
decode is feasible (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    segments=((("attn_local", "attn"), 23),),
    activation="swiglu",
    window_size=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,   # query_pre_attn_scalar = d_model/n_heads
    use_post_norm=True,
    scale_embedding=True,
    tie_embeddings=True,
    long_context_ok=True,
    source="arXiv:2408.00118",
)
