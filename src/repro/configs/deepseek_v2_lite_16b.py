"""DeepSeek-V2-Lite 16B: MLA attention (kv_lora=512), first layer dense MLP,
remaining layers MoE with 64 routed experts top-6 + 2 shared.
[arXiv:2405.04434]

The assignment header mentions both "64e top-6" and "160 routed"; 160 is the
full DeepSeek-V2 — the V2-Lite line (64 routed, top-6, 2 shared) is
authoritative here and matches the cited model.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,            # MLA: KV heads == heads after latent expansion
    head_dim=128,
    d_ff=10944,               # dense-MLP hidden for layer 0 (per model card)
    vocab_size=102400,
    segments=((("mla",), 1), (("mla_moe",), 26)),
    activation="swiglu",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                  nope_head_dim=128, rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff=1408),
    source="arXiv:2405.04434",
)
