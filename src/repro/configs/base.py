"""Model configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``.  A config is a
pure description: the model code in ``repro.models`` interprets it.  Layers are
described by *segments*: ``((pattern, repeats), ...)`` where ``pattern`` is a
tuple of block kinds.  Each segment is executed as a ``lax.scan`` over
``repeats`` stacked super-blocks, so compiled HLO size is depth-independent.

Block kinds
-----------
``attn``        global causal attention + dense MLP
``attn_local``  sliding-window causal attention + dense MLP
``attn_moe``    global causal attention + mixture-of-experts MLP
``mla``         DeepSeek multi-head latent attention + dense MLP
``mla_moe``     MLA + MoE (DeepSeek-V2 style: shared + routed experts)
``rglru``       Griffin/RecurrentGemma RG-LRU recurrent block + dense MLP
``mlstm``       xLSTM mLSTM block (matrix memory, parallelizable)
``slstm``       xLSTM sLSTM block (scalar memory, sequential scan)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

Segment = Tuple[Tuple[str, ...], int]

ATTENTION_KINDS = ("attn", "attn_local", "attn_moe", "mla", "mla_moe")
RECURRENT_KINDS = ("rglru", "mlstm", "slstm")
ALL_KINDS = ATTENTION_KINDS + RECURRENT_KINDS


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden size
    n_shared: int = 0               # DeepSeek-style always-on shared experts
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    # "einsum" = GShard one-hot dispatch (baseline); "gather" = sort-free
    # take/segment-sum dropless dispatch (beyond-paper perf variant).
    dispatch: str = "einsum"
    capacity_factor: float = 1.25
    group_size: int = 512           # tokens per dispatch group


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None   # None => direct q projection (V2-Lite)
    nope_head_dim: int = 128
    rope_head_dim: int = 64
    v_head_dim: int = 128
    # decode path: "naive" re-expands cached latents each step;
    # "absorbed" folds W_UK/W_UV into the query/output projections.
    decode_mode: str = "naive"


@dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0                  # 0 => d_model
    conv_width: int = 4
    c: float = 8.0                  # RG-LRU constant from Griffin


@dataclass(frozen=True)
class XLSTMConfig:
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv_width: int = 4
    chunk_size: int = 64            # mLSTM chunkwise-parallel chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Segment, ...]
    head_dim: int = 0               # 0 => d_model // n_heads
    activation: str = "swiglu"      # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window_size: Optional[int] = None       # for attn_local
    attn_softcap: Optional[float] = None    # gemma2 attention logit softcap
    final_softcap: Optional[float] = None   # gemma2 final logit softcap
    query_scale: Optional[float] = None     # None => 1/sqrt(head_dim)
    use_post_norm: bool = False             # gemma2 sandwich norms
    scale_embedding: bool = False           # gemma multiplies by sqrt(d_model)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: str = "none"          # none | vision | audio (stub embeddings)
    dtype: str = "bfloat16"
    # attention compute chunking (blockwise/flash attention)
    q_chunk: int = 512
    kv_chunk: int = 1024
    # training
    remat: bool = True
    source: str = ""                # citation for the config
    # serving sharding strategy: "tp" (tensor-parallel over the model axis;
    # the recorded baseline), "dp_cp" (weights replicated; batch over data,
    # sequence over model — right for small models where TP resharding
    # dominates), or "auto" (dp_cp for pure-attention archs whose replicated
    # weights fit ~2.5GB).  Baselines in EXPERIMENTS.md use "tp"; §Perf
    # documents the dp_cp wins.
    serve_strategy: str = "tp"
    # long_500k opt-in for non-subquadratic archs that remain feasible at
    # 500k decode (e.g. gemma2: half the layers are 4k-window local; global
    # layers decode in O(L) against a mesh-sharded KV cache).
    long_context_ok: bool = False

    def __post_init__(self):
        assert self.family in ("dense", "moe", "hybrid", "ssm", "vlm", "audio")
        total = sum(len(p) * r for p, r in self.segments)
        assert total == self.n_layers, (
            f"{self.name}: segments cover {total} layers != n_layers={self.n_layers}")
        for pattern, _ in self.segments:
            for kind in pattern:
                assert kind in ALL_KINDS, f"unknown block kind {kind}"
        if any(k in ("attn_moe", "mla_moe") for p, _ in self.segments for k in p):
            assert self.moe is not None
        if any(k.startswith("mla") for p, _ in self.segments for k in p):
            assert self.mla is not None
        if any(k == "rglru" for p, _ in self.segments for k in p):
            assert self.rglru is not None
        if any(k in ("mlstm", "slstm") for p, _ in self.segments for k in p):
            assert self.xlstm is not None

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        kinds: list[str] = []
        for pattern, repeats in self.segments:
            kinds.extend(pattern * repeats)
        return tuple(kinds)

    @property
    def is_subquadratic(self) -> bool:
        """True when no layer needs an unbounded full-attention KV cache."""
        for kind in self.layer_kinds:
            if kind in ("attn", "attn_moe", "mla", "mla_moe"):
                return False
        return True

    def supports_shape(self, shape_name: str) -> bool:
        if shape_name == "long_500k":
            if self.is_subquadratic or self.long_context_ok:
                return True
            return all(k in ("attn_local", "rglru", "mlstm", "slstm")
                       for k in self.layer_kinds)
        return True

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches init; used for rooflines)."""
        d, hd = self.d_model, self.resolved_head_dim
        nh, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d                  # output head
        for kind in self.layer_kinds:
            total += self._block_params(kind, d, hd, nh, nkv)
        total += d                                        # final norm
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE counts top_k + shared only)."""
        d, hd = self.d_model, self.resolved_head_dim
        nh, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for kind in self.layer_kinds:
            total += self._block_params(kind, d, hd, nh, nkv, active_only=True)
        total += d
        return total

    def _block_params(self, kind, d, hd, nh, nkv, active_only=False) -> int:
        n = 2 * d                                         # two pre-norms
        if self.use_post_norm:
            n += 2 * d
        if kind in ("attn", "attn_local", "attn_moe"):
            n += d * nh * hd + 2 * d * nkv * hd + nh * hd * d
            if self.qkv_bias:
                n += nh * hd + 2 * nkv * hd
        elif kind in ("mla", "mla_moe"):
            m = self.mla
            qd = m.nope_head_dim + m.rope_head_dim
            if m.q_lora_rank:
                n += d * m.q_lora_rank + m.q_lora_rank * nh * qd + m.q_lora_rank
            else:
                n += d * nh * qd
            n += d * (m.kv_lora_rank + m.rope_head_dim) + m.kv_lora_rank
            n += m.kv_lora_rank * nh * (m.nope_head_dim + m.v_head_dim)
            n += nh * m.v_head_dim * d
        elif kind == "rglru":
            r = self.rglru.d_rnn or d
            n += 2 * d * r + r * self.rglru.conv_width + 2 * r + 2 * r + r * d
        elif kind == "mlstm":
            x = self.xlstm
            di = int(d * x.proj_factor_mlstm)
            n += d * 2 * di + 3 * di * di // max(1, self.n_heads) * 0  # qkv below
            n += 3 * di * di + 2 * di + di * x.conv_width + di * d + di
        elif kind == "slstm":
            x = self.xlstm
            di = d
            n += 4 * d * di + 4 * (di // max(1, self.n_heads)) * di + 4 * di
            pf = x.proj_factor_slstm
            n += int(d * pf * d) * 2
        # feed-forward
        if kind in ("attn_moe", "mla_moe"):
            e = self.moe
            per_expert = 3 * d * e.d_ff if self.activation == "swiglu" else 2 * d * e.d_ff
            experts = (e.top_k + e.n_shared) if active_only else (e.n_experts + e.n_shared)
            n += experts * per_expert + d * e.n_experts   # router
        elif kind in ("attn", "attn_local", "mla", "rglru"):
            if self.d_ff:
                if self.activation == "swiglu":
                    n += 3 * d * self.d_ff
                else:
                    n += 2 * d * self.d_ff
        return n

    # ------------------------------------------------------------------
    def reduced(self, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        hd = 32
        nh = max(2, min(4, self.n_heads))
        nkv = max(1, min(nh, self.n_kv_heads if self.n_kv_heads < self.n_heads else nh))
        if nh % nkv:
            nkv = 1
        # keep one instance of each distinct block kind, in order
        seen, pattern = set(), []
        for k in self.layer_kinds:
            if k not in seen:
                seen.add(k)
                pattern.append(k)
        pattern = tuple(pattern[:n_layers]) if len(pattern) >= n_layers else tuple(pattern)
        reps = max(1, n_layers // len(pattern))
        segs = ((pattern, reps),)
        nl = len(pattern) * reps
        kw = dict(
            n_layers=nl, d_model=d_model, n_heads=nh, n_kv_heads=nkv,
            head_dim=hd, d_ff=(d_model * 2 if self.d_ff else 0),
            vocab_size=vocab, segments=segs,
            window_size=(64 if self.window_size else None),
            q_chunk=64, kv_chunk=64,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2,
                n_shared=min(1, self.moe.n_shared), d_ff=d_model, group_size=32)
        if self.mla:
            kw["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=64, nope_head_dim=32, rope_head_dim=16,
                v_head_dim=32)
        if self.rglru:
            kw["rglru"] = dataclasses.replace(self.rglru, d_rnn=d_model)
        if self.xlstm:
            kw["xlstm"] = dataclasses.replace(self.xlstm, chunk_size=16)
        return dataclasses.replace(self, name=self.name + "-smoke", **kw)


# ----------------------------------------------------------------------
# Input shape suite (assigned)
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
