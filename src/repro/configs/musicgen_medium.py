"""MusicGen-medium decoder backbone over EnCodec tokens; the conv/codec
frontend is stubbed — inputs are precomputed frame embeddings + codebook
tokens.  [arXiv:2306.05284]

Adaptation note: the original uses learned absolute positions; we use RoPE
for substrate uniformity (recorded in DESIGN.md).  MHA: n_kv_heads == n_heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    segments=((("attn",), 48),),
    activation="gelu",
    frontend="audio",
    source="arXiv:2306.05284 (EnCodec frontend stubbed per spec)",
)
