"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401

_ARCHS = {
    "pixtral-12b": "pixtral_12b",
    "musicgen-medium": "musicgen_medium",
    "gemma2-27b": "gemma2_27b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "phi3-medium-14b": "phi3_medium_14b",
    "nemotron-4-15b": "nemotron_4_15b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2-0.5b": "qwen2_0_5b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-350m": "xlstm_350m",
}


def list_archs() -> list[str]:
    return sorted(_ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch]}")
    return mod.CONFIG
