"""IBM Granite-3.0 1B-A400M: 32-expert top-8 MoE, every layer.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    segments=((("attn_moe",), 24),),
    activation="swiglu",
    moe=MoEConfig(n_experts=32, top_k=8, n_shared=0, d_ff=512),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
