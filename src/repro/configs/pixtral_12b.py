"""Pixtral-12B language backbone (Mistral-Nemo style) consuming ViT patch
embeddings from a stubbed vision frontend.  [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    segments=((("attn",), 40),),
    activation="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision",
    source="hf:mistralai/Pixtral-12B-2409 (ViT frontend stubbed per spec)",
)
