"""Nemotron-4 15B: GQA with squared-ReLU MLP.  [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    segments=((("attn",), 32),),
    activation="squared_relu",
    source="arXiv:2402.16819",
)
