"""xLSTM-350M: alternating mLSTM (matrix memory, chunkwise-parallel) and
sLSTM (scalar memory, sequential) blocks.  d_ff=0: blocks carry their own
up/down projections, no external FFN.  [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    segments=((("mlstm", "slstm"), 12),),
    activation="gelu",
    tie_embeddings=True,
    xlstm=XLSTMConfig(proj_factor_mlstm=2.0, proj_factor_slstm=4.0 / 3.0,
                      conv_width=4, chunk_size=64),
    source="arXiv:2405.04517",
)
