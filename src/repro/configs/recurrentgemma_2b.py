"""RecurrentGemma-2B (Griffin): RG-LRU recurrent blocks and 2048-window local
attention in a 2:1 pattern; 26 layers = 8×(rec,rec,attn) + (rec,rec).
[arXiv:2402.19427]"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,              # MQA on the local-attention layers
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    segments=((("rglru", "rglru", "attn_local"), 8), (("rglru", "rglru"), 1)),
    activation="gelu",
    window_size=2048,
    scale_embedding=True,
    tie_embeddings=True,
    rglru=RGLRUConfig(d_rnn=2560, conv_width=4, c=8.0),
    source="arXiv:2402.19427",
)
